"""SegmentStore: a directory of segments + an atomically-committed manifest.

The LSM structure (levels, runs, clock) lives in ``MANIFEST.json``; segment
files are immutable once finalized.  All mutations follow the classic LSM
commit protocol:

    1. write + fsync the new segment file(s)           (crash => orphan)
    2. write MANIFEST.json.tmp, fsync, os.replace      (the commit point)
    3. delete segment files no longer referenced       (crash => orphan)

``os.replace`` is atomic on POSIX, so the manifest always names a
consistent set of finalized segments: a crash *anywhere* leaves either the
old or the new manifest, plus possibly some orphan files that
:meth:`SegmentStore.recover` removes on the next open.  The in-memory
write buffer is covered separately by the write-ahead log
(:mod:`repro.ingest.wal`): ``wal-NNNNNN.log`` files live beside the
segments, the manifest's ``wal_start`` marks how much of the insert
stream the committed runs already contain, and the WAL is rotated down to
the still-buffered tail right after each manifest commit.  Recovery and
GC here deliberately leave ``wal-*`` files alone — they belong to the
log's own rotation protocol.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
from typing import Dict, List, Optional

from ..core import summarization as S
from ..core.metrics import IOStats
from .segment import Segment, SegmentFormatError, write_segment

__all__ = ["SegmentStore", "ShardDirectory", "MANIFEST_NAME", "SHARDS_NAME"]

MANIFEST_NAME = "MANIFEST.json"
SHARDS_NAME = "SHARDS.json"
_SEG_RE = re.compile(r"^seg-(\d{6})\.coco$")
_SHARD_DIR_RE = re.compile(r"^shard-\d{3}-g\d+$")
MANIFEST_VERSION = 1
SHARDS_VERSION = 1


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json_atomic(path: str, obj: dict) -> None:
    """Write + fsync ``path.tmp``, then ``os.replace`` — the one atomic
    commit primitive shared by per-shard manifests and the top-level
    shard manifest.  A crash leaves either the old file or the new one."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


@dataclasses.dataclass
class SegmentStore:
    """Manages ``root/seg-NNNNNN.coco`` files and ``root/MANIFEST.json``."""
    root: str
    io: Optional[IOStats] = None

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._next_id = 1 + max(
            [int(m.group(1)) for f in os.listdir(self.root)
             if (m := _SEG_RE.match(f))] or [0])

    # --------------------------------------------------------------- manifest
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST_NAME)

    def exists(self) -> bool:
        return os.path.exists(self.manifest_path)

    def load_manifest(self) -> Optional[dict]:
        if not self.exists():
            return None
        with open(self.manifest_path) as f:
            m = json.load(f)
        if m.get("version") != MANIFEST_VERSION:
            raise SegmentFormatError(
                f"{self.manifest_path}: unknown manifest version")
        return m

    def commit_manifest(self, manifest: dict) -> None:
        """Atomic manifest replace — THE commit point for every mutation."""
        manifest = dict(manifest, version=MANIFEST_VERSION)
        write_json_atomic(self.manifest_path, manifest)
        if self.io is not None:
            self.io.rand_write(1)

    @staticmethod
    def manifest_for(cfg: S.SummaryConfig, runs: List[dict],
                     **extra) -> dict:
        return {
            "version": MANIFEST_VERSION,
            "cfg": {"series_len": cfg.series_len,
                    "segments": cfg.segments, "bits": cfg.bits},
            "runs": runs,
            **extra,
        }

    @staticmethod
    def cfg_from_manifest(manifest: dict) -> S.SummaryConfig:
        return S.SummaryConfig(**manifest["cfg"])

    # --------------------------------------------------------------- segments
    def new_segment_path(self) -> str:
        name = f"seg-{self._next_id:06d}.coco"
        self._next_id += 1
        return os.path.join(self.root, name)

    def write_tree(self, tree) -> str:
        """Persist a ``CoconutTree`` as a fresh segment; returns its file
        name (relative to root).  NOT yet referenced by the manifest —
        commit separately."""
        path = self.new_segment_path()
        write_segment(path, tree, io=self.io)
        return os.path.basename(path)

    def open_segment(self, name: str) -> Segment:
        return Segment.open(os.path.join(self.root, name))

    def segment_files(self) -> List[str]:
        return sorted(f for f in os.listdir(self.root) if _SEG_RE.match(f))

    def live_files(self) -> List[str]:
        m = self.load_manifest()
        if m is None:
            return []
        return [r["file"] for r in m["runs"]]

    # --------------------------------------------------------------- recovery
    def recover(self) -> Dict[str, List[str]]:
        """Replay the commit protocol after a crash.

        * a leftover ``MANIFEST.json.tmp`` is an uncommitted commit —
          discarded (the committed manifest, if any, stays authoritative);
        * segment files not referenced by the manifest (orphans from a
          crash between steps 1-2 or 2-3) are deleted;
        * referenced segments must open cleanly (footer + header crc);
          a referenced-but-corrupt segment raises — that is data loss the
          caller must hear about, not silently drop.
        """
        report = {"removed": [], "kept": []}
        tmp = self.manifest_path + ".tmp"
        if os.path.exists(tmp):
            os.unlink(tmp)
            report["removed"].append(os.path.basename(tmp))
        live = set(self.live_files())
        for f in self.segment_files():
            if f not in live:
                os.unlink(os.path.join(self.root, f))
                report["removed"].append(f)
            else:
                seg = self.open_segment(f)   # raises SegmentFormatError
                seg.close()
                report["kept"].append(f)
        return report

    def gc(self) -> List[str]:
        """Delete finalized segments the manifest no longer references."""
        live = set(self.live_files())
        removed = []
        for f in self.segment_files():
            if f not in live:
                os.unlink(os.path.join(self.root, f))
                removed.append(f)
        return removed

    # --------------------------------------------------------------- lifetime
    def close(self) -> None:
        """Release the store.  Segments are opened per-operation and WAL
        handles are owned by the engine, so today this only marks the
        store closed for symmetry with ``CoconutLSM.close`` — examples and
        tests can rely on ``with SegmentStore(...) as store:`` shutting
        everything down deterministically."""

    def __enter__(self) -> "SegmentStore":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------ diagnostics
    def wal_bytes(self) -> int:
        """On-disk write-ahead-log footprint beside the segments."""
        from ..ingest.wal import WriteAheadLog
        return WriteAheadLog.wal_bytes(self.root)

    def total_bytes(self) -> int:
        return sum(os.path.getsize(os.path.join(self.root, f))
                   for f in self.segment_files())

    def describe(self) -> str:
        m = self.load_manifest()
        nruns = len(m["runs"]) if m else 0
        return (f"SegmentStore({self.root}: {len(self.segment_files())} "
                f"segments, {nruns} live runs, "
                f"{self.total_bytes() / 1e6:.2f} MB, "
                f"WAL {self.wal_bytes() / 1e3:.1f} kB)")


# ---------------------------------------------------------------------------
# Multi-shard namespace: one data dir, one atomic top-level manifest
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardDirectory:
    """One data directory holding N shard stores plus ``SHARDS.json``.

    Layout::

        root/
          SHARDS.json            <- the atomic top-level commit point
          shard-000-g0/          <- one full SegmentStore per shard
            MANIFEST.json  seg-*.coco  wal-*.log
          shard-001-g0/
          ...

    ``SHARDS.json`` records the shard count, the routing boundaries
    (z-order splitter keys), and which subdirectories are live.  It is
    committed with the same write-fsync-replace protocol as a per-shard
    manifest, so the *set of shards and their key ranges* changes
    atomically; each shard's contents stay crash-consistent through its
    own manifest + WAL.  Rebalancing migrations build a new generation of
    shard dirs, commit ``SHARDS.json`` pointing at them, then delete the
    old generation — :meth:`cleanup` removes dirs from either side of a
    crash (new-but-uncommitted, or old-but-superseded).
    """
    root: str
    io: Optional[IOStats] = None

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)

    @property
    def meta_path(self) -> str:
        return os.path.join(self.root, SHARDS_NAME)

    def exists(self) -> bool:
        return os.path.exists(self.meta_path)

    def load(self) -> Optional[dict]:
        if not self.exists():
            return None
        with open(self.meta_path) as f:
            meta = json.load(f)
        if meta.get("version") != SHARDS_VERSION:
            raise SegmentFormatError(
                f"{self.meta_path}: unknown shard-manifest version")
        return meta

    def commit(self, meta: dict) -> None:
        """Atomically publish shard count / boundaries / live dirs."""
        meta = dict(meta, version=SHARDS_VERSION)
        write_json_atomic(self.meta_path, meta)
        if self.io is not None:
            self.io.rand_write(1)

    @staticmethod
    def shard_dir_name(index: int, generation: int = 0) -> str:
        return f"shard-{index:03d}-g{generation}"

    def shard_store(self, name: str) -> SegmentStore:
        return SegmentStore(os.path.join(self.root, name), io=self.io)

    def shard_dirs_on_disk(self) -> List[str]:
        return sorted(d for d in os.listdir(self.root)
                      if _SHARD_DIR_RE.match(d)
                      and os.path.isdir(os.path.join(self.root, d)))

    def cleanup(self) -> List[str]:
        """Remove shard dirs the committed ``SHARDS.json`` doesn't
        reference — orphans of a crashed migration (either generation)
        — plus a torn ``SHARDS.json.tmp``.  Returns what was removed."""
        removed = []
        tmp = self.meta_path + ".tmp"
        if os.path.exists(tmp):
            os.unlink(tmp)
            removed.append(os.path.basename(tmp))
        meta = self.load()
        live = set(meta["dirs"]) if meta else set()
        for d in self.shard_dirs_on_disk():
            if d not in live:
                shutil.rmtree(os.path.join(self.root, d))
                removed.append(d)
        return removed

    def describe(self) -> str:
        meta = self.load()
        if meta is None:
            return f"ShardDirectory({self.root}: uncommitted)"
        stores = [self.shard_store(d) for d in meta["dirs"]]
        total = sum(s.total_bytes() for s in stores)
        wal = sum(s.wal_bytes() for s in stores)
        segs = sum(len(s.segment_files()) for s in stores)
        return (f"ShardDirectory({self.root}: {len(stores)} shards, "
                f"{segs} segments, {total / 1e6:.2f} MB, "
                f"WAL {wal / 1e3:.1f} kB)")

"""Compressed column codecs for segment format v3.

Coconut's storage pitch is that sortable summarizations shrink the index,
yet format v1/v2 spent a full byte per SAX symbol and 4 bytes per key
word regardless of ``cfg.bits``.  This module holds the two codecs the
v3 segment layout (and the tiered leaf cache built on top of it) uses to
make every byte of disk — and every byte of cache budget — hold more
leaves:

* **bit-packed codes** — each SAX word of ``w`` symbols at ``b`` bits is
  packed MSB-first into ``ceil(w*b/8)`` bytes.  Rows are packed
  *independently* (each row starts byte-aligned), so a leaf of packed
  rows is a plain contiguous slice and random leaf access needs no
  decoding context.  ``b == 8`` degenerates to the identity layout.

* **delta + zigzag-varint keys** — the sorted z-order key column is
  encoded per leaf: the leaf's first row is stored raw (``n_words``
  uint32 LE), every following row stores the per-word int64 delta from
  its predecessor as a zigzag LEB128 varint.  Sorted neighbours share
  their high words, so deltas are tiny.  Leaves decode independently
  through a byte-offset directory at the head of the column, matching
  the leaf-granular access pattern of the query pipeline and the cache.

Both codecs are exact (``decode(encode(x)) == x`` bit for bit) and
vectorized in numpy — no per-row Python loops on the hot decode path.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["packed_code_width", "pack_codes", "unpack_codes",
           "encode_keys", "PackedCodes", "PackedKeys"]


# ---------------------------------------------------------------------------
# Bit-packed SAX codes
# ---------------------------------------------------------------------------

def packed_code_width(w: int, b: int) -> int:
    """Bytes per packed code row: ``ceil(w*b/8)``."""
    return -(-(w * b) // 8)


def pack_codes(codes: np.ndarray, b: int) -> np.ndarray:
    """``[N, w]`` full-byte codes -> ``[N, ceil(w*b/8)]`` packed uint8.

    Symbol ``j`` of a row occupies bits ``[j*b, (j+1)*b)`` of that row's
    packed bytes, MSB-first; the final partial byte is zero-padded.
    """
    codes = np.ascontiguousarray(codes, np.uint8)
    if codes.ndim != 2:
        raise ValueError(f"codes must be 2-D, got shape {codes.shape}")
    if b == 8:
        return codes
    n, w = codes.shape
    if n == 0:
        return np.zeros((0, packed_code_width(w, b)), np.uint8)
    bits = np.unpackbits(codes[:, :, None], axis=2, count=8)[:, :, 8 - b:]
    return np.packbits(bits.reshape(n, w * b), axis=1)


def unpack_codes(packed: np.ndarray, w: int, b: int) -> np.ndarray:
    """``[N, ceil(w*b/8)]`` packed uint8 -> ``[N, w]`` full-byte codes."""
    packed = np.ascontiguousarray(packed, np.uint8)
    if b == 8:
        return packed
    squeeze = packed.ndim == 1
    if squeeze:
        packed = packed[None, :]
    n = packed.shape[0]
    if n == 0:
        out = np.zeros((0, w), np.uint8)
        return out[0] if squeeze else out
    bits = np.unpackbits(packed, axis=1, count=w * b).reshape(n, w, b)
    weight = (1 << np.arange(b - 1, -1, -1, dtype=np.uint8))
    out = (bits * weight[None, None, :]).sum(axis=2).astype(np.uint8)
    return out[0] if squeeze else out


class PackedCodes:
    """Decoding view over a packed code column (mmap or ndarray).

    Indexing (int / slice / fancy) reads only the touched packed rows and
    decodes them to full-byte ``[., w]`` uint8 — so existing call sites
    written against the v1 memmap keep working unchanged.  ``.packed``
    exposes the raw storage for paths that scan without decoding (the
    fused unpack+mindist kernel, the leaf cache, verbatim merge copies).
    """

    def __init__(self, packed, w: int, b: int):
        self._packed = packed
        self.w = int(w)
        self.b = int(b)

    @property
    def packed(self):
        return self._packed

    @property
    def packed_row_bytes(self) -> int:
        return packed_code_width(self.w, self.b)

    @property
    def shape(self):
        return (len(self._packed), self.w)

    @property
    def dtype(self):
        return np.dtype(np.uint8)

    @property
    def nbytes(self) -> int:
        """Logical (decoded) size; the stored size is ``packed.nbytes``."""
        return len(self._packed) * self.w

    def __len__(self) -> int:
        return len(self._packed)

    def __getitem__(self, idx) -> np.ndarray:
        return unpack_codes(np.asarray(self._packed[idx]), self.w, self.b)

    def __array__(self, dtype=None, copy=None):
        out = unpack_codes(np.asarray(self._packed), self.w, self.b)
        return out.astype(dtype) if dtype is not None else out


# ---------------------------------------------------------------------------
# Delta + zigzag-varint keys
# ---------------------------------------------------------------------------

def _zigzag(v: np.ndarray) -> np.ndarray:
    """int64 -> uint64 zigzag (small magnitudes -> small values)."""
    return ((v << 1) ^ (v >> 63)).view(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    zi = z.astype(np.int64, copy=False)
    return (zi >> 1) ^ -(zi & 1)


def _varint_encode(z: np.ndarray) -> np.ndarray:
    """uint64 values -> concatenated LEB128 bytes (vectorized)."""
    if len(z) == 0:
        return np.zeros(0, np.uint8)
    nb = np.ones(len(z), np.int64)
    for shift in (7, 14, 21, 28, 35, 42, 49, 56, 63):
        nb += (z >= np.uint64(1) << np.uint64(shift)).astype(np.int64)
    ends = np.cumsum(nb)
    starts = ends - nb
    buf = np.zeros(int(ends[-1]), np.uint8)
    for bi in range(10):
        m = nb > bi
        if not m.any():
            break
        vals = ((z[m] >> np.uint64(7 * bi)) & np.uint64(0x7F)).astype(
            np.uint8)
        cont = (nb[m] - 1 > bi).astype(np.uint8) << 7
        buf[starts[m] + bi] = vals | cont
    return buf


def _varint_decode(buf: np.ndarray, count: int) -> np.ndarray:
    """LEB128 bytes -> ``count`` uint64 values (vectorized reduceat)."""
    if count == 0:
        return np.zeros(0, np.uint64)
    buf = np.asarray(buf, np.uint8)
    ends_mask = (buf & 0x80) == 0
    end_pos = np.nonzero(ends_mask)[0]
    if len(end_pos) < count:
        raise ValueError("truncated varint stream")
    starts = np.empty(count, np.int64)
    starts[0] = 0
    starts[1:] = end_pos[:count - 1] + 1
    used = int(end_pos[count - 1]) + 1
    buf = buf[:used]
    vid = np.cumsum(np.concatenate(([0], ends_mask[:used - 1]))
                    .astype(np.int64))
    pos = np.arange(used, dtype=np.int64) - starts[vid]
    shifted = (buf & 0x7F).astype(np.uint64) << (7 * pos).astype(np.uint64)
    return np.add.reduceat(shifted, starts)


def _encode_key_leaf(rows: np.ndarray) -> bytes:
    """One leaf of sorted ``[m, nw]`` uint32 keys -> encoded bytes."""
    rows = np.ascontiguousarray(rows, np.uint32)
    out = rows[0].astype("<u4").tobytes()
    if len(rows) > 1:
        delta = rows[1:].astype(np.int64) - rows[:-1].astype(np.int64)
        out += _varint_encode(_zigzag(delta.ravel())).tobytes()
    return out


def encode_keys(keys: np.ndarray, leaf_size: int) -> np.ndarray:
    """Sorted ``[N, nw]`` uint32 keys -> the v3 keys column blob.

    Layout: ``uint64[n_leaves + 1]`` little-endian byte offsets (the leaf
    directory; entry 0 points just past the directory, the last entry is
    the blob length), followed by each leaf's encoded block.
    """
    keys = np.ascontiguousarray(keys, np.uint32)
    n = len(keys)
    n_leaves = -(-n // leaf_size) if n else 0
    blocks = [_encode_key_leaf(keys[s:s + leaf_size])
              for s in range(0, n, leaf_size)]
    offs = np.zeros(n_leaves + 1, np.uint64)
    offs[0] = 8 * (n_leaves + 1)
    for i, blk in enumerate(blocks):
        offs[i + 1] = offs[i] + len(blk)
    parts = [offs.astype("<u8").tobytes()] + blocks
    return np.frombuffer(b"".join(parts), np.uint8)


class PackedKeys:
    """Decoding view over a v3 delta+varint keys column blob.

    Behaves like the old ``[N, n_words]`` uint32 memmap for indexing, but
    decodes leaf-at-a-time through the directory so a one-leaf probe
    touches only that leaf's bytes.  ``leaf_nbytes`` reports a leaf's
    *stored* size — what a cache hit on the leaf actually saves.
    """

    def __init__(self, blob, n: int, n_words: int, leaf_size: int):
        self._blob = blob
        self.n = int(n)
        self.n_words = int(n_words)
        self.leaf_size = int(leaf_size)
        self.n_leaves = -(-self.n // self.leaf_size) if self.n else 0
        head = np.asarray(blob[:8 * (self.n_leaves + 1)], np.uint8)
        self._dir = np.frombuffer(head.tobytes(), "<u8").astype(np.int64)

    @property
    def shape(self):
        return (self.n, self.n_words)

    @property
    def dtype(self):
        return np.dtype(np.uint32)

    @property
    def nbytes(self) -> int:
        """Logical (decoded) size; stored size is ``stored_nbytes``."""
        return self.n * self.n_words * 4

    @property
    def stored_nbytes(self) -> int:
        return len(self._blob)

    def __len__(self) -> int:
        return self.n

    def leaf_nbytes(self, li: int) -> int:
        """Stored bytes of leaf ``li`` (the cache's saved-bytes figure)."""
        return int(self._dir[li + 1] - self._dir[li])

    def decode_leaf(self, li: int) -> np.ndarray:
        """Leaf ``li`` as decoded ``[m, n_words]`` uint32 rows."""
        s, e = int(self._dir[li]), int(self._dir[li + 1])
        m = min(self.leaf_size, self.n - li * self.leaf_size)
        nw = self.n_words
        raw = np.asarray(self._blob[s:e], np.uint8)
        first = np.frombuffer(raw[:4 * nw].tobytes(), "<u4")
        if m == 1:
            return first[None, :].astype(np.uint32)
        z = _varint_decode(raw[4 * nw:], (m - 1) * nw)
        delta = _unzigzag(z).reshape(m - 1, nw)
        words = np.cumsum(
            np.vstack([first.astype(np.int64), delta]), axis=0)
        return words.astype(np.uint32)

    def _decode_range(self, s: int, e: int) -> np.ndarray:
        if e <= s:
            return np.zeros((0, self.n_words), np.uint32)
        l0, l1 = s // self.leaf_size, (e - 1) // self.leaf_size
        parts = [self.decode_leaf(li) for li in range(l0, l1 + 1)]
        block = parts[0] if len(parts) == 1 else np.concatenate(parts)
        base = l0 * self.leaf_size
        return block[s - base:e - base]

    def __getitem__(self, idx):
        if isinstance(idx, (int, np.integer)):
            i = int(idx)
            if i < 0:
                i += self.n
            li = i // self.leaf_size
            return self.decode_leaf(li)[i - li * self.leaf_size]
        if isinstance(idx, slice):
            s, e, step = idx.indices(self.n)
            out = self._decode_range(s, e)
            return out[::step] if step != 1 else out
        idx = np.asarray(idx)
        if len(idx) == 0:
            return np.zeros((0, self.n_words), np.uint32)
        out = np.empty((len(idx), self.n_words), np.uint32)
        leaves = idx // self.leaf_size
        for li in np.unique(leaves):
            m = leaves == li
            out[m] = self.decode_leaf(int(li))[idx[m] - li * self.leaf_size]
        return out

    def __array__(self, dtype=None, copy=None):
        out = self._decode_range(0, self.n)
        return out.astype(dtype) if dtype is not None else out

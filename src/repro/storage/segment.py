"""On-disk Coconut segment: one sorted run as a contiguous binary file.

The paper's central storage claim (Sec. 4.3, and the sequential-write
analysis of arXiv 2006.13713) is that sortable summarizations let the whole
index live in a *contiguous on-disk array* written with large sequential
appends — no tree of scattered pages.  A segment file is exactly that
array, laid out column-major so each query touches only the columns it
needs:

    +--------------------------------------------------------------+
    | header (512 B): magic, crc, flags, n, SummaryConfig, layout  |
    +--------------------------------------------------------------+
    | codes       [N, ceil(w*b/8)] uint8  bit-packed SAX words     |
    | paas        [N, w]       float32  PAA values (sorted order)  |
    | offsets     [N]          int64    position in original file  |
    | timestamps  [N]          int64    (optional)                 |
    | raw         [N, L]       float32  (optional; co-sorted when  |
    |                                    materialized, original    |
    |                                    order otherwise)          |
    | fences      [ceil(N/leaf), n_words] uint32  leaf-first keys  |
    | ids         [N]          int64    global row ids (optional)  |
    | keys        <variable>   delta+zigzag-varint encoded, with a |
    |                          per-leaf byte directory (format v3) |
    +--------------------------------------------------------------+
    | footer (20 B): magic, n, header-crc echo                     |
    +--------------------------------------------------------------+

**Format v3** (current): the codes column is bit-packed to ``cfg.bits``
bits per symbol and the sorted keys column is delta+varint encoded per
leaf (see :mod:`repro.storage.packing`) — Coconut's storage-cost claim
made real on disk and in the tiered leaf cache.  Versions 1/2 (full-byte
codes, fixed-width keys placed first in the column chain) remain fully
readable: :meth:`Segment.open` detects the version and the ``keys`` /
``codes`` properties present the same decoded view either way, so every
consumer — and every search answer — is version-agnostic.

Every column is 64-byte aligned and carries a crc32.  The header embeds
the ``SummaryConfig`` so a segment is self-describing; the footer is
written *last*, so a file without a valid footer is an interrupted write
and is discarded during recovery (see :mod:`repro.storage.store`).

Reading is zero-copy for the fixed columns: :class:`Segment` exposes each
as an ``np.memmap`` (packed columns behind thin decoding views), and
:func:`exact_search_mmap` streams the code column through the existing
mindist kernels chunk-wise, charging the *actual* bytes touched to
:class:`repro.core.metrics.IOStats` — the paper's I/O accounting finally
measures real I/O instead of a model.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib
from typing import Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import summarization as S
from ..core.metrics import IOStats
from .packing import (PackedCodes, PackedKeys, encode_keys, pack_codes,
                      packed_code_width)

__all__ = ["Segment", "SegmentWriter", "write_segment",
           "exact_search_mmap", "SegmentFormatError",
           "MAGIC", "FOOTER_MAGIC", "HEADER_SIZE", "FOOTER_SIZE",
           "VERSION", "LEGACY_VERSIONS"]

MAGIC = b"COCOSEG1"
FOOTER_MAGIC = b"COCOFIN1"
HEADER_SIZE = 512
FOOTER_SIZE = 20
_ALIGN = 64
VERSION = 3                 # packed codes + delta/varint keys
LEGACY_VERSIONS = (1, 2)    # full-byte codes, fixed-width keys

# flags
F_MATERIALIZED = 1 << 0    # raw block is co-sorted with the keys
F_HAS_TS = 1 << 1          # timestamps column present
F_HAS_RAW = 1 << 2         # raw block present
F_HAS_IDS = 1 << 3         # global row ids column present

# "ids" appended LAST so the positional column table of pre-ids files
# still parses: their header's 8th entry reads as zero padding (0, 0, 0),
# which matches the absent-column layout when F_HAS_IDS is clear.
_COLUMNS = ("keys", "codes", "paas", "offsets", "timestamps", "raw",
            "fences", "ids")
_DTYPES = {
    "keys": np.uint32, "codes": np.uint8, "paas": np.float32,
    "offsets": np.int64, "timestamps": np.int64, "raw": np.float32,
    "fences": np.uint32, "ids": np.int64,
}

# header: magic, crc, version, flags, n, L, w, b, leaf, n_words, n_fences
_HEAD_FMT = "<8sIHHQIIIIII"
_COL_FMT = "<QQI"          # per column: offset, nbytes, crc32
_FOOT_FMT = "<8sQI"        # magic, n, header-crc echo


class SegmentFormatError(RuntimeError):
    """Raised when a segment file is missing, truncated, or corrupt."""


def _align(off: int) -> int:
    return -(-off // _ALIGN) * _ALIGN


def _layout(n: int, cfg: S.SummaryConfig, leaf_size: int,
            has_ts: bool, has_raw: bool, has_ids: bool = False,
            version: int = VERSION) -> dict:
    """Column name -> (offset, nbytes, shape).  Deterministic given the
    header fields, so the writer can place columns before any data exists.

    Format v3 places the variable-length keys blob *after* the fixed
    columns: its entry carries ``(None, None, shape)`` here and the real
    ``(offset, nbytes)`` lives in the header's column table (written at
    finalize, once the encoded size is known).  ``__var__`` marks where
    that blob starts; for legacy versions the keys column sits first in
    the fixed chain exactly as v1 wrote it.
    """
    w, nw, L = cfg.segments, cfg.n_words, cfg.series_len
    n_fences = -(-n // leaf_size) if n else 0
    code_w = packed_code_width(w, cfg.bits) if version >= 3 else w
    shapes = {
        "keys": (n, nw), "codes": (n, code_w), "paas": (n, w),
        "offsets": (n,), "timestamps": (n,) if has_ts else None,
        "raw": (n, L) if has_raw else None,
        "fences": (n_fences, nw),
        "ids": (n,) if has_ids else None,
    }
    out, off = {}, HEADER_SIZE
    for name in _COLUMNS:
        shape = shapes[name]
        if shape is None:
            out[name] = (0, 0, None)
            continue
        if name == "keys" and version >= 3:
            out[name] = (None, None, shape)
            continue
        nbytes = int(np.prod(shape, dtype=np.int64)) * \
            np.dtype(_DTYPES[name]).itemsize
        off = _align(off)
        out[name] = (off, nbytes, shape)
        off += nbytes
    out["__var__"] = (_align(off), 0, None)
    # v3's footer lands after the keys blob — position resolved at
    # finalize (writer) / from the header's keys entry (reader)
    out["__footer__"] = ((None if version >= 3 else _align(off)),
                         FOOTER_SIZE, None)
    return out


class SegmentWriter:
    """Streaming segment writer: large sequential appends per column.

    ``n`` (the total entry count) must be known up front — exactly what the
    external-sort build provides after its chunking pass — so every column
    region has a fixed place and each region is filled strictly
    sequentially.  The header is written twice: a zeroed placeholder first
    (an interrupted write is therefore unreadable), the real one at
    :meth:`finalize` after the footer, then fsync.

    Writes format v3 by default (packed codes, delta/varint keys);
    ``version=1`` reproduces the legacy full-byte layout byte for byte
    (migration tests build old-format fixtures through it).  ``append``
    accepts codes either full-width ``[m, w]`` (packed here) or already
    packed ``[m, ceil(w*b/8)]`` (copied verbatim — the external-sort merge
    path, which never needs the decoded bytes).
    """

    def __init__(self, path: str, cfg: S.SummaryConfig, n: int, *,
                 leaf_size: int = 256, materialized: bool = True,
                 has_timestamps: bool = False, has_raw: bool = True,
                 has_ids: bool = False,
                 io: Optional[IOStats] = None,
                 version: int = VERSION):
        if materialized and not has_raw:
            raise ValueError("materialized segment requires the raw block")
        if version != VERSION and version not in LEGACY_VERSIONS:
            raise ValueError(f"unwritable segment version {version}")
        self.path = path
        self.cfg = cfg
        self.n = int(n)
        self.leaf_size = int(leaf_size)
        self.materialized = bool(materialized)
        self.has_ts = bool(has_timestamps)
        self.has_raw = bool(has_raw)
        self.has_ids = bool(has_ids)
        self.io = io
        self.version = int(version)
        self._layout = _layout(self.n, cfg, self.leaf_size,
                               self.has_ts, self.has_raw, self.has_ids,
                               version=self.version)
        self._pos = {name: 0 for name in _COLUMNS}   # rows written per col
        self._crc = {name: 0 for name in _COLUMNS}
        self._fences: list[np.ndarray] = []
        self._key_parts: list[np.ndarray] = []       # v3: buffered keys
        self._f = open(path, "w+b")
        self._f.write(b"\0" * HEADER_SIZE)

    # ------------------------------------------------------------------ write
    def _put(self, name: str, arr: np.ndarray) -> None:
        off, nbytes, shape = self._layout[name]
        if shape is None:
            raise ValueError(f"segment has no {name!r} column")
        arr = np.ascontiguousarray(arr, dtype=_DTYPES[name])
        want = shape[1:] if len(shape) > 1 else ()
        if arr.shape[1:] != want:
            raise ValueError(f"{name}: row shape {arr.shape[1:]} != {want}")
        row_bytes = arr.dtype.itemsize * int(np.prod(want, dtype=np.int64)
                                             or 1)
        start = self._pos[name]
        if start + len(arr) > self.n:
            raise ValueError(f"{name}: {start + len(arr)} rows > n={self.n}")
        buf = arr.tobytes()
        self._f.seek(off + start * row_bytes)
        self._f.write(buf)
        self._crc[name] = zlib.crc32(buf, self._crc[name])
        self._pos[name] = start + len(arr)
        if self.io is not None:
            self.io.write_bytes(len(buf))
            self.io.seq_write(len(arr))

    def _put_codes(self, codes: np.ndarray) -> None:
        """Route codes through the packer when the target layout packs."""
        codes = np.asarray(codes)
        if self.version >= 3:
            w = self.cfg.segments
            pw = packed_code_width(w, self.cfg.bits)
            if codes.ndim == 2 and codes.shape[1] == w and pw != w:
                codes = pack_codes(codes, self.cfg.bits)
        self._put("codes", codes)

    def append(self, keys: np.ndarray, codes: np.ndarray, paas: np.ndarray,
               offsets: np.ndarray,
               timestamps: Optional[np.ndarray] = None,
               raw: Optional[np.ndarray] = None,
               ids: Optional[np.ndarray] = None) -> None:
        """Append a batch of *sorted-order* rows to every sorted column.

        ``raw`` is required (and co-sorted) iff the segment is
        materialized; for non-materialized segments the original-order raw
        block is streamed separately via :meth:`append_raw`.
        """
        keys = np.ascontiguousarray(keys, np.uint32)
        start = self._pos["keys"]
        if self.version >= 3:
            if start + len(keys) > self.n:
                raise ValueError(
                    f"keys: {start + len(keys)} rows > n={self.n}")
            self._key_parts.append(keys)
            self._pos["keys"] = start + len(keys)
        else:
            self._put("keys", keys)
        self._put_codes(codes)
        self._put("paas", paas)
        self._put("offsets", offsets)
        if self.has_ts:
            if timestamps is None:
                raise ValueError("segment expects timestamps")
            self._put("timestamps", timestamps)
        if self.has_ids:
            if ids is None:
                raise ValueError("segment expects global row ids")
            self._put("ids", ids)
        if self.materialized:
            if raw is None:
                raise ValueError("materialized segment expects raw rows")
            self._put("raw", raw)
        # collect leaf-first keys (every leaf_size-th global row) as fences
        idx = np.arange(start, start + len(keys))
        mask = idx % self.leaf_size == 0
        if mask.any():
            self._fences.append(keys[mask])

    def append_raw(self, rows: np.ndarray) -> None:
        """Append original-order raw rows (non-materialized segments)."""
        if self.materialized:
            raise ValueError("materialized raw is appended via append()")
        self._put("raw", rows)

    # --------------------------------------------------------------- finalize
    def finalize(self) -> None:
        for name in _COLUMNS:
            off, nbytes, shape = self._layout[name]
            if name == "fences" or shape is None:
                continue
            want = shape[0]
            if self._pos[name] != want:
                raise ValueError(
                    f"{name}: wrote {self._pos[name]} rows, expected {want}")
        fences = (np.concatenate(self._fences) if self._fences
                  else np.zeros((0, self.cfg.n_words), np.uint32))
        self._put("fences", fences)
        if self.version >= 3:
            keys = (np.concatenate(self._key_parts) if self._key_parts
                    else np.zeros((0, self.cfg.n_words), np.uint32))
            blob = encode_keys(keys, self.leaf_size)
            buf = blob.tobytes()
            var_off = self._layout["__var__"][0]
            self._f.seek(var_off)
            self._f.write(buf)
            self._crc["keys"] = zlib.crc32(buf)
            self._layout["keys"] = (var_off, len(buf),
                                    self._layout["keys"][2])
            self._layout["__footer__"] = (_align(var_off + len(buf)),
                                          FOOTER_SIZE, None)
            if self.io is not None:
                self.io.write_bytes(len(buf))
                self.io.seq_write(len(keys))
        header = self._header_bytes()
        head_crc, = struct.unpack_from("<I", header, 8)
        foot_off = self._layout["__footer__"][0]
        self._f.seek(foot_off)
        self._f.write(struct.pack(_FOOT_FMT, FOOTER_MAGIC, self.n,
                                  head_crc))
        self._f.seek(0)
        self._f.write(header)
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        if self.io is not None:
            self.io.write_bytes(HEADER_SIZE + FOOTER_SIZE)

    def abort(self) -> None:
        self._f.close()
        if os.path.exists(self.path):
            os.unlink(self.path)

    def _header_bytes(self) -> bytes:
        flags = ((F_MATERIALIZED if self.materialized else 0)
                 | (F_HAS_TS if self.has_ts else 0)
                 | (F_HAS_RAW if self.has_raw else 0)
                 | (F_HAS_IDS if self.has_ids else 0))
        n_fences = self._layout["fences"][2][0]
        head = bytearray(HEADER_SIZE)
        struct.pack_into(_HEAD_FMT, head, 0, MAGIC, 0, self.version, flags,
                         self.n, self.cfg.series_len, self.cfg.segments,
                         self.cfg.bits, self.leaf_size, self.cfg.n_words,
                         n_fences)
        pos = struct.calcsize(_HEAD_FMT)
        for name in _COLUMNS:
            off, nbytes, shape = self._layout[name]
            struct.pack_into(_COL_FMT, head, pos,
                             off if shape is not None else 0, nbytes,
                             self._crc[name])
            pos += struct.calcsize(_COL_FMT)
        crc = zlib.crc32(bytes(head[12:]))
        struct.pack_into("<I", head, 8, crc)
        return bytes(head)


def write_segment(path: str, tree, *, io: Optional[IOStats] = None,
                  version: int = VERSION) -> None:
    """Persist an in-memory ``CoconutTree`` as one segment file.

    One large sequential write per column — the O(N/B) sequential-write
    cost of the paper's bulk load, now against a real file.
    """
    has_ts = tree.timestamps is not None
    has_raw = tree.raw is not None or tree.raw_ref is not None
    has_ids = tree.ids is not None
    w = SegmentWriter(path, tree.cfg, tree.n, leaf_size=tree.leaf_size,
                      materialized=tree.materialized,
                      has_timestamps=has_ts, has_raw=has_raw,
                      has_ids=has_ids, io=io, version=version)
    try:
        w.append(np.asarray(tree.keys), np.asarray(tree.codes),
                 np.asarray(tree.paas), np.asarray(tree.offsets),
                 timestamps=(np.asarray(tree.timestamps)
                             if has_ts else None),
                 raw=np.asarray(tree.raw) if tree.materialized else None,
                 ids=np.asarray(tree.ids) if has_ids else None)
        if has_raw and not tree.materialized:
            w.append_raw(np.asarray(tree.raw_ref))
        w.finalize()
    except BaseException:
        w.abort()
        raise


# ---------------------------------------------------------------------------
# Reader
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Segment:
    """mmap-backed view of one segment file (open with :meth:`open`)."""
    path: str
    cfg: S.SummaryConfig
    n: int
    leaf_size: int
    materialized: bool
    columns: dict                    # name -> np.memmap (or None)
    column_crcs: dict                # name -> stored crc32
    nbytes: int                      # file size on disk
    version: int = VERSION
    _keys_view: Optional[PackedKeys] = dataclasses.field(
        default=None, repr=False, compare=False)
    _codes_view: Optional[PackedCodes] = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def open(cls, path: str) -> "Segment":
        try:
            size = os.path.getsize(path)
            with open(path, "rb") as f:
                head = f.read(HEADER_SIZE)
        except OSError as e:
            raise SegmentFormatError(f"{path}: {e}") from e
        if len(head) < HEADER_SIZE:
            raise SegmentFormatError(f"{path}: truncated header")
        (magic, crc, version, flags, n, L, w, b, leaf, nw,
         n_fences) = struct.unpack_from(_HEAD_FMT, head, 0)
        if magic != MAGIC:
            raise SegmentFormatError(f"{path}: bad magic {magic!r}")
        if zlib.crc32(head[12:]) != crc:
            raise SegmentFormatError(f"{path}: header checksum mismatch")
        if version != VERSION and version not in LEGACY_VERSIONS:
            raise SegmentFormatError(f"{path}: unknown version {version}")
        cfg = S.SummaryConfig(series_len=L, segments=w, bits=b)
        if cfg.n_words != nw:
            raise SegmentFormatError(f"{path}: n_words {nw} inconsistent")
        pos = struct.calcsize(_HEAD_FMT)
        cols, crcs = {}, {}
        lay = _layout(n, cfg, leaf,
                      bool(flags & F_HAS_TS), bool(flags & F_HAS_RAW),
                      bool(flags & F_HAS_IDS), version=version)
        keys_end = 0
        for name in _COLUMNS:
            off, nbytes, col_crc = struct.unpack_from(_COL_FMT, head, pos)
            pos += struct.calcsize(_COL_FMT)
            want_off, want_bytes, shape = lay[name]
            if shape is None:
                if nbytes:
                    raise SegmentFormatError(
                        f"{path}: unexpected {name} column")
                cols[name] = None
                continue
            if name == "keys" and version >= 3:
                # variable-length blob: the header's (offset, nbytes) is
                # authoritative, anchored at the deterministic var start
                if off != lay["__var__"][0] or off + nbytes > size:
                    raise SegmentFormatError(
                        f"{path}: keys layout mismatch")
                crcs[name] = col_crc
                cols[name] = (np.memmap(path, dtype=np.uint8, mode="r",
                                        offset=off, shape=(nbytes,))
                              if nbytes else np.zeros(0, np.uint8))
                keys_end = off + nbytes
                continue
            if (off, nbytes) != (want_off, want_bytes):
                raise SegmentFormatError(
                    f"{path}: {name} layout mismatch")
            if off + nbytes > size:
                raise SegmentFormatError(f"{path}: {name} beyond EOF")
            crcs[name] = col_crc
            if nbytes == 0:
                cols[name] = np.zeros(shape, _DTYPES[name])
            else:
                cols[name] = np.memmap(path, dtype=_DTYPES[name],
                                       mode="r", offset=off, shape=shape)
        foot_off = (_align(keys_end) if version >= 3
                    else lay["__footer__"][0])
        if foot_off + FOOTER_SIZE > size:
            raise SegmentFormatError(f"{path}: missing footer "
                                     "(interrupted write)")
        with open(path, "rb") as f:
            f.seek(foot_off)
            foot = f.read(FOOTER_SIZE)
        fmagic, fn, fcrc = struct.unpack(_FOOT_FMT, foot)
        if fmagic != FOOTER_MAGIC or fn != n or fcrc != crc:
            raise SegmentFormatError(f"{path}: bad footer "
                                     "(interrupted write)")
        seg = cls(path=path, cfg=cfg, n=n, leaf_size=leaf,
                  materialized=bool(flags & F_MATERIALIZED),
                  columns=cols, column_crcs=crcs, nbytes=size,
                  version=version)
        if version >= 3:
            seg._keys_view = PackedKeys(cols["keys"], n, nw, leaf)
            seg._codes_view = PackedCodes(cols["codes"], w, b)
        return seg

    # ------------------------------------------------------------ column views
    @property
    def keys(self):
        """Decoded ``[N, n_words]`` uint32 view (indexable like a memmap;
        v3 decodes leaf-at-a-time through :class:`PackedKeys`)."""
        return self._keys_view if self.version >= 3 else \
            self.columns["keys"]

    @property
    def codes(self):
        """Decoded ``[N, w]`` uint8 view (v3 unpacks on access)."""
        return self._codes_view if self.version >= 3 else \
            self.columns["codes"]

    @property
    def codes_packed(self) -> Optional[np.ndarray]:
        """Raw packed code storage ``[N, ceil(w*b/8)]`` (None on legacy
        files) — the zero-decode input of the fused unpack+mindist kernel
        and the block the leaf cache keeps resident."""
        return self.columns["codes"] if self.version >= 3 else None

    @property
    def code_row_bytes(self) -> int:
        """Stored bytes per code row (what a code read actually costs)."""
        return (packed_code_width(self.cfg.segments, self.cfg.bits)
                if self.version >= 3 else self.cfg.segments)

    def keys_leaf_nbytes(self, li: int) -> int:
        """Stored bytes of one leaf of the keys column."""
        if self.version >= 3:
            return self._keys_view.leaf_nbytes(li)
        s = li * self.leaf_size
        e = min(s + self.leaf_size, self.n)
        return (e - s) * self.cfg.n_words * 4

    @property
    def paas(self) -> np.memmap:
        return self.columns["paas"]

    @property
    def offsets(self) -> np.memmap:
        return self.columns["offsets"]

    @property
    def timestamps(self) -> Optional[np.memmap]:
        return self.columns["timestamps"]

    @property
    def raw(self) -> Optional[np.memmap]:
        return self.columns["raw"]

    @property
    def ids(self) -> Optional[np.memmap]:
        return self.columns["ids"]

    @property
    def fences(self) -> np.memmap:
        return self.columns["fences"]

    def verify(self) -> None:
        """Full-content check: recompute every column crc32 (reads all)."""
        for name, mm in self.columns.items():
            if mm is None or not isinstance(mm, np.memmap):
                continue
            got = zlib.crc32(mm.tobytes())
            if got != self.column_crcs[name]:
                raise SegmentFormatError(
                    f"{self.path}: {name} checksum mismatch")

    def series_rows(self, sorted_idx: np.ndarray,
                    io: Optional[IOStats] = None) -> np.ndarray:
        """Raw rows for sorted-order indices (handles both raw layouts)."""
        if self.raw is None:
            raise SegmentFormatError(f"{self.path}: no raw block on disk")
        if self.materialized:
            rows = np.asarray(self.raw[sorted_idx])
        else:
            rows = np.asarray(self.raw[np.asarray(self.offsets[sorted_idx])])
        if io is not None:
            io.read_bytes(rows.nbytes)
        return rows

    def to_tree(self):
        """Load the segment into an in-memory/device ``CoconutTree``.

        The columns are already sorted on disk, so this is a straight
        sequential read — no re-sorting — and searches on the result are
        bit-identical to the tree that produced the segment (packed
        columns decode exactly; pack/unpack is the identity round trip).
        """
        from ..core.tree import CoconutTree
        ts = self.timestamps
        mat = self.materialized
        raw = None
        raw_ref = None
        if self.raw is not None:
            block = jnp.asarray(np.asarray(self.raw))
            raw, raw_ref = (block, None) if mat else (None, block)
        ids = self.ids
        return CoconutTree(
            keys=jnp.asarray(np.asarray(self.keys)),
            codes=jnp.asarray(np.asarray(self.codes)),
            paas=jnp.asarray(np.asarray(self.paas)),
            offsets=jnp.asarray(np.asarray(self.offsets)).astype(jnp.int32),
            raw=raw, raw_ref=raw_ref,
            timestamps=(None if ts is None
                        else jnp.asarray(np.asarray(ts))),
            ids=(None if ids is None
                 else jnp.asarray(np.asarray(ids))),
            cfg=self.cfg, leaf_size=self.leaf_size)

    def iter_sorted(self, batch: int = 8192
                    ) -> Iterator[Tuple[np.ndarray, ...]]:
        """Yield (keys, codes, paas, offsets[, ts][, raw]) batches in key
        order — the sequential-read side of a k-way merge.

        On v3 files the codes element is the *packed* ``[m, ceil(w*b/8)]``
        uint8 rows, never a full-width decode: each packed row is
        independently byte-aligned, so the merge can copy rows verbatim
        into a new segment (``SegmentWriter.append`` accepts packed rows)
        and the round trip stays bit-exact with zero decode work.
        """
        codes_src = (self.columns["codes"] if self.version >= 3
                     else self.codes)
        for s in range(0, self.n, batch):
            e = min(s + batch, self.n)
            out = [np.asarray(self.keys[s:e]), np.asarray(codes_src[s:e]),
                   np.asarray(self.paas[s:e]),
                   np.asarray(self.offsets[s:e])]
            out.append(None if self.timestamps is None
                       else np.asarray(self.timestamps[s:e]))
            out.append(None if (self.raw is None or not self.materialized)
                       else np.asarray(self.raw[s:e]))
            yield tuple(out)

    def close(self) -> None:
        self._keys_view = None
        self._codes_view = None
        for name, mm in list(self.columns.items()):
            if isinstance(mm, np.memmap):
                del mm
            self.columns[name] = None


# ---------------------------------------------------------------------------
# Zero-copy query path: chunk-wise SIMS over the mmap'd columns
# ---------------------------------------------------------------------------

def exact_search_mmap(seg: Segment, queries: np.ndarray, *,
                      k: int = 1, chunk: int = 8192,
                      radius_leaves: int = 1,
                      io: Optional[IOStats] = None,
                      mindist_fn=None,
                      budget=None,
                      mode: str = "exact",
                      ) -> Tuple[np.ndarray, np.ndarray, "object"]:
    """Exact k-NN straight off the segment file (SIMS, Algorithm 5).

    The segment is just another backend of the unified query pipeline
    (:mod:`repro.query`): the on-disk fence column prices every leaf
    with its z-order envelope mindist, the executor streams ONLY the
    surviving leaves' code rows from the mmap (skip-sequential — pruned
    leaves' pages are never touched), and unpruned rows are fetched from
    the raw block for verification.  Every byte that actually crosses
    the storage boundary is charged to ``io`` (``bytes_read``), so
    cold-vs-warm benchmarks measure real page-cache behavior.

    ``budget`` / ``mode="approx"``: budgeted best-first drain with the
    certified gap report (see :mod:`repro.query.approx`) — leaves the
    budget leaves unvisited are never streamed off disk, so ``max_bytes``
    bounds real I/O within one leaf's granularity.

    Returns ``(dists [Q, k], offsets [Q, k], SearchStats)`` — answers
    bit-identical to :func:`repro.core.tree.exact_search_batch` on the
    same data.
    """
    from ..query import Partition, approx_knn, exact_knn
    if seg.raw is None:
        raise SegmentFormatError(
            f"{seg.path}: exact search needs the raw block on disk")
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    if mode not in ("exact", "approx"):
        raise ValueError(f"mode must be 'exact' or 'approx', got {mode!r}")
    if budget is not None or mode == "approx":
        return approx_knn([Partition.from_segment(seg)], queries, seg.cfg,
                          k=k, budget=budget,
                          radius_leaves=radius_leaves, chunk=chunk,
                          io=io, mindist_fn=mindist_fn)
    return exact_knn([Partition.from_segment(seg)], queries, seg.cfg,
                     k=k, radius_leaves=radius_leaves, chunk=chunk,
                     io=io, mindist_fn=mindist_fn)

"""External-sort bulk load (paper Algorithm 3, now with real spill files).

The in-memory ``CoconutTree.build`` assumes the whole dataset fits on
device.  This module is the paper's actual construction story: summarize
and sort fixed-size chunks on device, spill each sorted chunk to disk as a
segment file (one large sequential write), then k-way merge the sorted
spills into ONE contiguous output segment (sequential reads in, one
sequential write out) — O(N/B) block transfers end to end, for datasets
bounded by disk rather than device/host RAM.

Stability contract: chunks are processed in input order, each chunk is
sorted stably on device (``lexsort``), and the merge tie-breaks equal keys
by (chunk index, row-within-chunk).  The resulting order is therefore
*identical* to a stable in-memory sort of the full input — external-sort
builds are bit-equal to ``CoconutTree.build``, which the test suite
asserts.
"""
from __future__ import annotations

import heapq
import os
from typing import Iterable, Iterator, Optional, Union

import jax.numpy as jnp
import numpy as np

from ..core import keys as K
from ..core import summarization as S
from ..core.metrics import IOStats
from .segment import Segment, SegmentWriter

__all__ = ["build_external"]

Chunks = Union[np.ndarray, "jnp.ndarray", Iterable[np.ndarray]]


def _iter_chunks(raw: Chunks, chunk_size: int) -> Iterator[np.ndarray]:
    if hasattr(raw, "shape") and hasattr(raw, "__getitem__"):
        arr = raw
        for s in range(0, int(arr.shape[0]), chunk_size):
            yield np.asarray(arr[s: s + chunk_size], np.float32)
    else:
        for c in raw:
            yield np.asarray(c, np.float32)


def _sorted_chunk(raw_c: np.ndarray, cfg: S.SummaryConfig, znorm: bool):
    """Summarize + stable-sort one chunk on device; return host columns."""
    x = jnp.asarray(raw_c, jnp.float32)
    if znorm:
        x = S.znormalize(x)
    paas, codes = S.summarize(x, cfg)
    keys = S.invsax_keys(codes, cfg)
    order = K.lexsort_keys(keys)
    return (np.asarray(keys[order]), np.asarray(codes[order]),
            np.asarray(paas[order]), np.asarray(order),
            np.asarray(x[order]))


def _spill_rows(seg: Segment, si: int, batch: int,
                io: Optional[IOStats]):
    """Yield one merge-heap item per row of a sorted spill, in order.

    The item key is ``(key-words tuple, chunk index, row index)`` so the
    merge is totally ordered and stable — see the module docstring.
    """
    r_global = 0
    for keys, codes, paas, offs, ts, raw in seg.iter_sorted(batch=batch):
        if io is not None:
            io.read_bytes(keys.nbytes + codes.nbytes + paas.nbytes
                          + offs.nbytes
                          + (ts.nbytes if ts is not None else 0)
                          + (raw.nbytes if raw is not None else 0))
            io.seq_read(len(keys))
        for r in range(len(keys)):
            key = (tuple(int(v) for v in keys[r]), si, r_global)
            yield (key, codes[r], paas[r], offs[r],
                   None if ts is None else ts[r], raw[r])
            r_global += 1


def build_external(raw: Chunks, cfg: S.SummaryConfig, *,
                   workdir: str,
                   chunk_size: int = 65536,
                   leaf_size: int = 256,
                   timestamps: Optional[np.ndarray] = None,
                   znorm: bool = False,
                   out_path: Optional[str] = None,
                   merge_batch: int = 4096,
                   keep_spills: bool = False,
                   io: Optional[IOStats] = None) -> Segment:
    """Bulk-load one on-disk segment from data larger than device memory.

    ``raw`` is either an array ``[N, L]`` or an iterable of ``[m, L]``
    chunks (the larger-than-RAM path; at most one chunk is resident at a
    time).  Returns the opened output :class:`Segment`; load it with
    ``.to_tree()`` or query it in place with
    :func:`repro.storage.segment.exact_search_mmap`.

    Only the materialized (Coconut-Tree-Full) layout is supported: the
    merge streams raw rows into their sorted position, which is exactly
    the full-data materialization whose sequential-write advantage
    arXiv 2006.13713 quantifies.
    """
    if timestamps is not None and not (hasattr(raw, "shape")):
        raise ValueError("timestamps require array (not iterator) input")
    os.makedirs(workdir, exist_ok=True)
    out_path = out_path or os.path.join(workdir, "external.coco")
    has_ts = timestamps is not None

    # -- pass 1: summarize + sort fixed-size chunks, spill each to disk -----
    spill_paths = []
    start = 0
    for ci, raw_c in enumerate(_iter_chunks(raw, chunk_size)):
        m = raw_c.shape[0]
        keys, codes, paas, order, raw_sorted = _sorted_chunk(
            raw_c, cfg, znorm)
        path = os.path.join(workdir, f"spill-{ci:04d}.coco")
        w = SegmentWriter(path, cfg, m, leaf_size=leaf_size,
                          materialized=True, has_timestamps=has_ts,
                          has_raw=True, io=io)
        try:
            ts_c = (np.asarray(timestamps[start: start + m])[order]
                    if has_ts else None)
            w.append(keys, codes, paas,
                     (start + order).astype(np.int64),
                     timestamps=ts_c, raw=raw_sorted)
            w.finalize()
        except BaseException:
            w.abort()
            raise
        spill_paths.append(path)
        start += m
    n_total = start

    # -- pass 2: k-way merge the sorted spills into ONE contiguous segment --
    spills = [Segment.open(p) for p in spill_paths]
    out = SegmentWriter(out_path, cfg, n_total, leaf_size=leaf_size,
                        materialized=True, has_timestamps=has_ts,
                        has_raw=True, io=io)
    bufs = {name: [] for name in
            ("keys", "codes", "paas", "offsets", "ts", "raw")}

    def _flush_bufs():
        if not bufs["keys"]:
            return
        out.append(np.stack(bufs["keys"]), np.stack(bufs["codes"]),
                   np.stack(bufs["paas"]),
                   np.asarray(bufs["offsets"], np.int64),
                   timestamps=(np.asarray(bufs["ts"], np.int64)
                               if has_ts else None),
                   raw=np.stack(bufs["raw"]))
        for b in bufs.values():
            b.clear()

    try:
        streams = [_spill_rows(seg, si, merge_batch, io)
                   for si, seg in enumerate(spills)]
        for key, code, paa, off, ts, row in heapq.merge(
                *streams, key=lambda item: item[0]):
            bufs["keys"].append(np.asarray(key[0], np.uint32))
            bufs["codes"].append(code)
            bufs["paas"].append(paa)
            bufs["offsets"].append(int(off))
            if has_ts:
                bufs["ts"].append(int(ts))
            bufs["raw"].append(row)
            if len(bufs["keys"]) >= merge_batch:
                _flush_bufs()
        _flush_bufs()
        out.finalize()
    except BaseException:
        out.abort()
        raise
    finally:
        for seg in spills:
            seg.close()
        if not keep_spills:
            for p in spill_paths:
                if os.path.exists(p):
                    os.unlink(p)
    return Segment.open(out_path)

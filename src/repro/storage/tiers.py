"""Heat-driven tiered leaf store: device-hot, host-warm, mmap-cold.

"Data Series Indexing Gone Parallel" (PAPERS.md) makes the scan
compute-bound by keeping the hot summarization columns resident;
Coconut's sortable layout makes residency *leaf-granular* — every column
is leaf-contiguous on disk, so a leaf is both the pruning unit and the
natural cache block.  This module stacks three tiers under the
:class:`repro.query.partition.Partition` seam:

* **cold** — the mmap'd v3 segment columns, exactly as before.  First
  touch of a leaf reads its packed bytes, charges ``io.bytes_read``, and
  admits the block to the warm tier.
* **warm** — a byte-budgeted host-RAM :class:`ClockCache` of packed code
  blocks and decoded key blocks.  A hit serves the block with zero disk
  I/O and charges ``cache.bytes_saved`` instead of ``io.bytes_read``
  (the two currencies never mix, so the analytics gate's bit-exact
  byte accounting still certifies).
* **hot** — leaves whose clock touch count crosses ``promote_touches``
  get their packed code block copied to device (``jnp.asarray``) inside
  a smaller device byte budget.  The executor's fused unpack+mindist
  kernel then scans them without a host→device transfer per probe.

Admission is purely demand + touch heat — the same per-leaf touch
signal ``repro.obs.analytics`` aggregates into ``WORKLOAD.json`` leaf
heat, observed here at its source.  Invalidation is two-sided:

* leaf blocks are keyed by segment path, and segment files are
  immutable-once-published with never-reused ids, so the only
  invalidation event is a segment leaving the store (GC after
  flush/merge/rebalance) — :meth:`TieredLeafStore.invalidate` drops that
  group;
* whole-probe answers in the :class:`QueryResultCache` are keyed by the
  snapshot's **data epoch** (bumped on every buffer insert, run publish,
  and merge), so a result computed against an older view is simply
  unreachable.

Everything is mirrored into the obs registry under ``cache.*`` and
scraped by ``/metrics`` and ``serve.py``'s final report.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Hashable, List, Optional, Tuple

import numpy as np

from ..obs.registry import MetricsRegistry, get_registry
from .cache import CacheEntry, ClockCache, QueryResultCache

__all__ = ["TieredLeafStore"]


class TieredLeafStore:
    """The shared leaf-block cache handed to every Partition of an LSM
    (or one per shard).  Thread-safe: concurrent probes hit it from the
    executor pool.

    ``capacity_bytes`` bounds host-resident block bytes;
    ``device_capacity_bytes`` (default: a quarter of it) separately
    bounds the subset additionally promoted to device.
    """

    def __init__(self, capacity_bytes: int, *,
                 device_capacity_bytes: Optional[int] = None,
                 promote_touches: int = 4,
                 result_entries: int = 512,
                 registry: Optional[MetricsRegistry] = None):
        self.cache = ClockCache(int(capacity_bytes),
                                on_evict=self._on_evict)
        self.device_capacity_bytes = (
            int(capacity_bytes) // 4 if device_capacity_bytes is None
            else int(device_capacity_bytes))
        self.promote_touches = int(promote_touches)
        self.result_cache = QueryResultCache(result_entries)
        self._reg = registry if registry is not None else get_registry()
        self._dev_lock = threading.Lock()
        self._device_bytes = 0
        # invalidation fan-out: other device-resident caches (the mesh
        # scan engine's pinned shard columns) subscribe here so segment
        # GC after flush/merge/rebalance drops THEIR state too
        self._inval_hooks: List[Callable[[Optional[Hashable]], None]] = []
        # own monotone totals (the registry is process-global; these are
        # this store's view, what serve.py's final report prints)
        self.hits = 0
        self.misses = 0
        self.bytes_saved = 0
        self.promotions = 0
        # eager registration: the full cache.* family is present in the
        # /metrics exposition from the first scrape, not first touch
        for c in ("hits", "misses", "bytes_saved", "promotions",
                  "evictions", "insertions", "result_hits",
                  "result_misses"):
            self._reg.counter(f"cache.{c}")
        self._publish_gauges()

    # ------------------------------------------------------------ leaf blocks
    def get(self, token: Hashable, col: str, leaf: int,
            stored_nbytes: int) -> Optional[Any]:
        """The cached block for (segment, column, leaf) or None.

        ``stored_nbytes`` is what the block costs to read off disk —
        the amount a hit credits to ``cache.bytes_saved`` in place of
        the ``io.bytes_read`` charge a miss would incur.
        """
        ent = self.cache.get((token, col, leaf))
        if ent is None:
            self.misses += 1
            self._reg.counter("cache.misses").inc()
            return None
        self.hits += 1
        self.bytes_saved += int(stored_nbytes)
        self._reg.counter("cache.hits").inc()
        self._reg.counter("cache.bytes_saved").inc(int(stored_nbytes))
        if (col == "codes" and not ent.device
                and ent.touches >= self.promote_touches):
            self._promote(ent)
        return ent.value

    def admit(self, token: Hashable, col: str, leaf: int,
              value: np.ndarray, stored_nbytes: int) -> None:
        """Admit a freshly-read block to the warm tier (demand fill)."""
        ent = self.cache.put((token, col, leaf), value,
                             int(value.nbytes))
        if ent is not None:
            self._reg.counter("cache.insertions").inc()
        self._publish_gauges()

    def _promote(self, ent: CacheEntry) -> None:
        """Copy a hot packed-code block to device, within budget."""
        with self._dev_lock:
            if ent.device:
                return
            if self._device_bytes + ent.nbytes > self.device_capacity_bytes:
                return
            self._device_bytes += ent.nbytes
            ent.device = True
        import jax.numpy as jnp
        ent.value = jnp.asarray(np.asarray(ent.value))
        self.promotions += 1
        self._reg.counter("cache.promotions").inc()
        self._reg.gauge("cache.device_bytes").set(self._device_bytes)

    def _on_evict(self, key, ent: CacheEntry) -> None:
        self._reg.counter("cache.evictions").inc()
        if ent.device:
            with self._dev_lock:
                self._device_bytes -= ent.nbytes
                ent.device = False

    # ----------------------------------------------------------- invalidation
    def add_invalidation_hook(
            self, fn: Callable[[Optional[Hashable]], None]) -> None:
        """Subscribe ``fn(token)`` to every invalidation event.  Called
        with the retired segment token on :meth:`invalidate` and with
        ``None`` on :meth:`clear`.  Hooks must be cheap and must not
        raise (they run on the compactor/rebalance thread)."""
        self._inval_hooks.append(fn)

    def _fire_invalidation(self, token: Optional[Hashable]) -> None:
        for fn in list(self._inval_hooks):
            fn(token)

    def invalidate(self, token: Hashable) -> int:
        """Drop every cached leaf of one segment (called when the
        segment file is garbage-collected after a merge/rebalance)."""
        n = self.cache.invalidate_group(token)
        self._publish_gauges()
        self._fire_invalidation(token)
        return n

    def clear(self) -> None:
        self.cache.clear()
        self.result_cache.clear()
        self._publish_gauges()
        self._fire_invalidation(None)

    # ----------------------------------------------------------- result cache
    def result_get(self, key: Tuple) -> Optional[Any]:
        val = self.result_cache.get(key)
        self._reg.counter("cache.result_hits" if val is not None
                          else "cache.result_misses").inc()
        return val

    def result_put(self, key: Tuple, value: Any) -> None:
        self.result_cache.put(key, value)

    # --------------------------------------------------------------- readouts
    def _publish_gauges(self) -> None:
        self._reg.gauge("cache.resident_bytes").set(
            self.cache.resident_bytes)
        self._reg.gauge("cache.entries").set(len(self.cache))
        self._reg.gauge("cache.device_bytes").set(self._device_bytes)

    @property
    def device_bytes(self) -> int:
        with self._dev_lock:
            return self._device_bytes

    def stats(self) -> dict:
        """Point-in-time summary for serve.py's final report."""
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else 0.0,
            "bytes_saved": self.bytes_saved,
            "resident_bytes": self.cache.resident_bytes,
            "device_bytes": self.device_bytes,
            "entries": len(self.cache),
            "promotions": self.promotions,
            "evictions": self.cache.evictions,
            "insertions": self.cache.insertions,
            "result_hits": self.result_cache.hits,
            "result_misses": self.result_cache.misses,
        }

"""Persistent storage engine: on-disk segments, manifest store, external sort.

See docs/ARCHITECTURE.md ("Storage engine") for the segment layout, the
manifest commit protocol, and the recovery rules.
"""
from .external_sort import build_external
from .segment import (Segment, SegmentFormatError, SegmentWriter,
                      exact_search_mmap, write_segment)
from .store import SegmentStore, ShardDirectory

__all__ = ["Segment", "SegmentWriter", "SegmentFormatError",
           "SegmentStore", "ShardDirectory", "build_external",
           "exact_search_mmap", "write_segment"]
